"""L2: Llama-architecture model (RMSNorm, RoPE, causal attention, SiLU-gated
FFN) with runtime-switchable activation/KV fake-quantization.

Everything here is lowered once by ``aot.py`` to HLO text; the Rust
coordinator feeds weights/activations as PJRT literals at runtime. The
per-token quantization path calls the L1 Pallas kernel so it lowers into the
same HLO module.

Weight convention: ``W[Cout, Cin]``, ``y = x @ W.T`` (matches
rust/src/model/layout.rs).
"""

import jax
import jax.numpy as jnp

from . import quant
from .configs import ModelConfig, ACT_POINTS
from .kernels.per_token_quant import per_token_quant


def rmsnorm(x, g, eps=1e-5):
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(ms + eps) * g


def rope(x):
    """Rotary embedding over x[B, S, H, Hd] (half-split convention)."""
    b, s, h, hd = x.shape
    half = hd // 2
    pos = jnp.arange(s, dtype=jnp.float32)[:, None]
    inv = 1.0 / (10000.0 ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = pos * inv[None, :]                     # [S, half]
    cos = jnp.cos(ang)[None, :, None, :]
    sin = jnp.sin(ang)[None, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def _act_stats(x):
    """Per-tensor (min, max) + per-channel amax — calibration food for the L3
    static-scale pass and for SmoothQuant."""
    flat = x.reshape(-1, x.shape[-1])
    return (jnp.minimum(flat.min(), 0.0), jnp.maximum(flat.max(), 0.0),
            jnp.abs(flat).max(axis=0))


class ActQuant:
    """Branchless runtime-dispatched activation quantizer.

    ``flags = (act_on, per_token, kv_on)`` are f32 0/1 scalars;
    ``static`` maps point name -> (scale, zp) f32 scalars.
    """

    def __init__(self, static, flags, qmax_a, qmax_kv):
        self.static = static
        self.act_on, self.per_token, self.kv_on = flags
        self.qmax_a = qmax_a
        self.qmax_kv = qmax_kv

    def __call__(self, point, x):
        scale, zp = self.static[point]
        x_tok = per_token_quant(x, self.qmax_a)
        x_st = quant.fakequant_static(x, scale, zp, self.qmax_a)
        x_q = jnp.where(self.per_token > 0.5, x_tok, x_st)
        return jnp.where(self.act_on > 0.5, x_q, x)

    def kv(self, x):
        x_q = per_token_quant(x, self.qmax_kv)
        return jnp.where(self.kv_on > 0.5, x_q, x)


class NoQuant:
    """FP path; records activation stats and the raw activations at the four
    quant points (the L3 calibration pass feeds these to static-scale
    calibration, SmoothQuant/AWQ statistics, and GPTQ Hessians)."""

    def __init__(self):
        self.stats = {}
        self.acts = {}

    def __call__(self, point, x):
        self.stats[point] = _act_stats(x)
        self.acts[point] = x
        return x

    def kv(self, x):
        return x


def block_fwd(cfg: ModelConfig, ws, norms, x, aq):
    """One Transformer block. ``ws`` = (wq,wk,wv,wo,wg,wu,wd), ``norms`` =
    (norm_attn, norm_ffn), ``aq`` an ActQuant or NoQuant."""
    wq_, wk_, wv_, wo_, wg_, wu_, wd_ = ws
    na, nf = norms
    b, s, d = x.shape
    h, hd = cfg.heads, cfg.head_dim

    xa = aq("attn_in", rmsnorm(x, na))
    q = (xa @ wq_.T).reshape(b, s, h, hd)
    k = (xa @ wk_.T).reshape(b, s, h, hd)
    v = (xa @ wv_.T).reshape(b, s, h, hd)
    q, k = rope(q), rope(k)
    # per-token asymmetric KV-cache quantization (Fig. 8), post-RoPE
    k = aq.kv(k.reshape(b, s, d)).reshape(b, s, h, hd)
    v = aq.kv(v.reshape(b, s, d)).reshape(b, s, h, hd)

    qt = q.transpose(0, 2, 1, 3)                     # [B,H,S,hd]
    kt = k.transpose(0, 2, 3, 1)                     # [B,H,hd,S]
    scores = (qt @ kt) / jnp.sqrt(jnp.float32(hd))
    mask = jnp.tril(jnp.ones((s, s), jnp.float32))
    scores = jnp.where(mask[None, None] > 0.5, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)          # softmax input stays FP
    attn = (probs @ v.transpose(0, 2, 1, 3)).transpose(0, 2, 1, 3)
    attn = attn.reshape(b, s, d)

    o = aq("o_in", attn) @ wo_.T
    hidd = x + o

    xf = aq("ffn_in", rmsnorm(hidd, nf))
    gate = jax.nn.silu(xf @ wg_.T) * (xf @ wu_.T)
    y = hidd + aq("down_in", gate) @ wd_.T
    return y


def embed(emb, ids):
    """ids[B,S] int32 -> x[B,S,D]."""
    return emb[ids]


def head_logprobs(x, final_norm, head_w, targets):
    """Final norm + logits; returns (mean NLL, per-position logprob of
    ``targets``). Rust masks/sums slices of the per-position array to score
    multiple-choice continuations (lm-eval-harness rule)."""
    xn = rmsnorm(x, final_norm)
    logits = xn @ head_w.T
    logz = jax.nn.logsumexp(logits, axis=-1)
    tgt = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    logp = tgt - logz
    return -logp.mean(), logp


def model_fwd(cfg: ModelConfig, params, ids):
    """Full FP forward: params = (emb, tuple_of_blocks, final_norm, head_w),
    each block = (ws7, norms2)."""
    emb, blocks, final_norm, head_w = params
    x = embed(emb, ids)
    for (ws, norms) in blocks:
        x = block_fwd(cfg, ws, norms, x, NoQuant())
    return x, final_norm, head_w


def lm_loss(cfg: ModelConfig, params, ids, targets):
    x, final_norm, head_w = model_fwd(cfg, params, ids)
    loss, _ = head_logprobs(x, final_norm, head_w, targets)
    return loss
