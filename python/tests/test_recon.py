"""Reconstruction-step tests: RTN start point, loss decrease for every method,
LRQ-vs-FlexRound parameter counting, ablation wiring."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from compile import quant
from compile import recon as R
from compile import model as M
from compile.configs import CONFIGS, block_weight_shapes, ACT_POINTS

CFG = CONFIGS["tiny"]
QMAXW = jnp.float32(15.0)   # 4-bit: big enough error for learning to matter


def make_block(rng, scale=0.05):
    ws = tuple(jnp.asarray(rng.normal(size=sh) * scale, jnp.float32)
               for _, sh in block_weight_shapes(CFG))
    norms = (jnp.ones((CFG.d,), jnp.float32), jnp.ones((CFG.d,), jnp.float32))
    return ws, norms


def rtn_init(ws, qmax):
    s1s, zs = [], []
    for w in ws:
        s1, z = quant.rtn_range(w, qmax)
        s1s.append(s1)
        zs.append(z)
    return s1s, zs


def make_theta(method, ws, rank, rng):
    thetas = []
    for w in ws:
        cout, cin = w.shape
        ds1 = jnp.zeros((cout,), jnp.float32)
        if method == "lrq":
            thetas.append((ds1,
                           jnp.zeros((cout, rank), jnp.float32),
                           jnp.asarray(rng.normal(size=(rank, cin)) * 0.01,
                                       jnp.float32),
                           jnp.zeros((cout,), jnp.float32),
                           jnp.zeros((cin,), jnp.float32)))
        elif method == "lrq_nobias":
            thetas.append((ds1,
                           jnp.zeros((cout, rank), jnp.float32),
                           jnp.asarray(rng.normal(size=(rank, cin)) * 0.01,
                                       jnp.float32)))
        elif method == "fr":
            thetas.append((ds1, jnp.zeros((cout, cin), jnp.float32)))
    return tuple(thetas)


def fp_flags():
    return (jnp.float32(0.0), jnp.float32(0.0), jnp.float32(0.0))


def static_scales():
    return tuple((jnp.float32(1.0), jnp.float32(0.0)) for _ in ACT_POINTS)


@pytest.mark.parametrize("method,rank", [("lrq", 8), ("lrq_nobias", 8),
                                         ("fr", 0)])
def test_recon_loss_decreases(method, rank, rng):
    ws, norms = make_block(rng)
    s1s, zs = rtn_init(ws, QMAXW)
    theta = make_theta(method, ws, rank, rng)
    zeros = jax.tree_util.tree_map(jnp.zeros_like, theta)
    m, v = zeros, zeros

    x = jnp.asarray(rng.normal(size=(CFG.recon_batch, CFG.seq, CFG.d)),
                    jnp.float32)
    y_t = M.block_fwd(CFG, ws, norms, x, M.NoQuant())

    step = jax.jit(R.make_recon_step(CFG, method, rank))
    losses = []
    for i in range(30):
        loss, theta, m, v = step(
            x, y_t, ws, norms, tuple(s1s), tuple(zs), theta, m, v,
            jnp.float32(i), jnp.float32(3e-3), static_scales(), fp_flags(),
            QMAXW, jnp.float32(255.0), jnp.float32(255.0))
        losses.append(float(loss))
    assert losses[-1] < losses[0] * 0.9, losses


def test_zero_theta_step0_equals_rtn_loss(rng):
    """At init (S terms zero) LRQ and FlexRound start from the same RTN loss."""
    ws, norms = make_block(rng)
    s1s, zs = rtn_init(ws, QMAXW)
    x = jnp.asarray(rng.normal(size=(CFG.recon_batch, CFG.seq, CFG.d)),
                    jnp.float32)
    y_t = M.block_fwd(CFG, ws, norms, x, M.NoQuant())

    losses = {}
    for method, rank in [("lrq", 8), ("fr", 0)]:
        theta = make_theta(method, ws, rank, np.random.default_rng(0))
        if method == "lrq":
            # zero U2 so L2U2 == 0 exactly at init
            theta = tuple((t[0], t[1], jnp.zeros_like(t[2]), t[3], t[4])
                          for t in theta)
        zeros = jax.tree_util.tree_map(jnp.zeros_like, theta)
        step = R.make_recon_step(CFG, method, rank)
        loss, *_ = step(x, y_t, ws, norms, tuple(s1s), tuple(zs), theta,
                        zeros, zeros,
                        jnp.float32(0.0), jnp.float32(0.0), static_scales(),
                        fp_flags(), QMAXW, jnp.float32(255.0),
                        jnp.float32(255.0))
        losses[method] = float(loss)
    assert_allclose(losses["lrq"], losses["fr"], rtol=1e-5)


def test_theta_spec_param_counts():
    """Table 29: LRQ learnable-parameter ratio ~40% of weights at the default
    rank; FlexRound ratio > 100% (full S2 + s1)."""
    def count(method, rank):
        total = 0
        for _, (co, ci) in block_weight_shapes(CFG):
            for _, sh in R.theta_spec(method, co, ci, rank):
                n = 1
                for d in sh:
                    n *= d
                total += n
        return total

    weights = sum(co * ci for _, (co, ci) in block_weight_shapes(CFG))
    lrq_ratio = count("lrq", CFG.rank) / weights
    fr_ratio = count("fr", 0) / weights
    assert 0.2 < lrq_ratio < 0.6
    assert fr_ratio > 1.0
    assert count("lrq_nobias", CFG.rank) < count("lrq", CFG.rank)


def test_lrq_fewer_params_than_fr_all_ranks():
    for r in CFG.ranks:
        for _, (co, ci) in block_weight_shapes(CFG):
            lrq = sum(int(np.prod(sh)) for _, sh in R.theta_spec("lrq", co, ci, r))
            fr = sum(int(np.prod(sh)) for _, sh in R.theta_spec("fr", co, ci, r))
            if r <= 32:
                assert lrq < fr
