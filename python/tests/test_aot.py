"""AOT contract tests: artifact I/O specs match the lowered HLO entry layout,
and the manifest grammar round-trips."""

import re

import jax.numpy as jnp
import pytest

from compile import aot
from compile.configs import CONFIGS


CFG = CONFIGS["tiny"]


def _entry_param_count(hlo_text):
    m = re.search(r"entry_computation_layout=\{\((.*?)\)->", hlo_text,
                  re.DOTALL)
    assert m, "no entry layout"
    inner = m.group(1)
    # count top-level f32[...]/s32[...] params
    return len(re.findall(r"(?:f32|s32)\[", inner))


@pytest.mark.parametrize("build", [
    aot.build_embed, aot.build_head_loss, aot.build_block_fwd,
    aot.build_block_fwd_q, aot.build_kernel_fakequant, aot.build_kernel_qmm,
])
def test_input_count_matches_hlo(build):
    art = build(CFG)
    text = art.lower()
    assert _entry_param_count(text) == len(art.inputs), art.name


def test_recon_input_count_matches_hlo():
    art = aot.build_recon(CFG, "lrq", 8)
    text = art.lower()
    assert _entry_param_count(text) == len(art.inputs)


def test_manifest_grammar():
    arts = {CFG.name: [aot.build_embed(CFG), aot.build_block_fwd(CFG)]}
    lines = aot.manifest_lines([CFG], arts)
    assert lines[0] == "version 1"
    assert any(l.startswith("config tiny ") for l in lines)
    n_art = sum(1 for l in lines if l.startswith("artifact "))
    n_end = sum(1 for l in lines if l == "end")
    assert n_art == n_end == 2
    # every in/out line: name dtype dims...
    for l in lines:
        if l.startswith(("in ", "out ")):
            parts = l.split()
            assert parts[2] in ("f32", "i32")
            for d in parts[3:]:
                assert d.isdigit()


def test_scalar_dims_empty():
    art = aot.build_head_loss(CFG)
    lines = aot.manifest_lines([CFG], {CFG.name: [art]})
    loss_lines = [l for l in lines if l.startswith("out loss")]
    assert loss_lines == ["out loss f32"]
