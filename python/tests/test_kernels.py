"""L1 correctness: every Pallas kernel against its pure-jnp oracle, including
hypothesis sweeps over shapes / ranks / bit-widths and gradient agreement.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile import quant
from compile.kernels import ref
from compile.kernels.lrq_fakequant import lrq_fakequant, lrq_fakequant_kernel
from compile.kernels.per_token_quant import per_token_quant, per_token_quant_kernel
from compile.kernels.quant_matmul import quant_matmul


def _lrq_inputs(rng, cout, cin, r, bits, scale=0.02):
    w = jnp.asarray(rng.normal(size=(cout, cin)), jnp.float32)
    qmax = jnp.float32(2.0 ** bits - 1.0)
    s1, z = quant.rtn_range(w, qmax)
    l2 = jnp.asarray(rng.normal(size=(cout, r)) * scale, jnp.float32)
    u2 = jnp.asarray(rng.normal(size=(r, cin)) * scale, jnp.float32)
    r2 = jnp.asarray(rng.normal(size=(cout,)) * scale, jnp.float32)
    c2 = jnp.asarray(rng.normal(size=(cin,)) * scale, jnp.float32)
    return w, s1, z, l2, u2, r2, c2, qmax


class TestLrqFakequant:
    def test_matches_ref_exact(self, rng):
        args = _lrq_inputs(rng, 96, 160, 16, 8)
        out_k = lrq_fakequant_kernel(*args)
        out_r = ref.lrq_fakequant_ref(*args)
        assert_allclose(np.asarray(out_k), np.asarray(out_r), atol=1e-6)

    @settings(max_examples=25, deadline=None)
    @given(
        cout=st.sampled_from([8, 32, 96, 128, 352]),
        cin=st.sampled_from([8, 24, 128, 352]),
        r=st.sampled_from([1, 2, 8, 32]),
        bits=st.sampled_from([3, 4, 8]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hypothesis_shapes_bits(self, cout, cin, r, bits, seed):
        rng = np.random.default_rng(seed)
        args = _lrq_inputs(rng, cout, cin, r, bits)
        out_k = lrq_fakequant_kernel(*args)
        out_r = ref.lrq_fakequant_ref(*args)
        assert_allclose(np.asarray(out_k), np.asarray(out_r), atol=1e-5)

    @settings(max_examples=10, deadline=None)
    @given(bm=st.sampled_from([16, 32, 48, 96]),
           bn=st.sampled_from([20, 40, 80, 160]))
    def test_tile_invariance(self, bm, bn):
        """Output must not depend on the BlockSpec tiling."""
        rng = np.random.default_rng(7)
        args = _lrq_inputs(rng, 96, 160, 8, 8)
        base = lrq_fakequant_kernel(*args)
        tiled = lrq_fakequant_kernel(*args, bm=bm, bn=bn)
        assert_allclose(np.asarray(tiled), np.asarray(base), atol=1e-6)

    def test_zero_exponent_is_rtn(self, rng):
        """L2=U2=r2=c2=0 must reduce LRQ (Eq. 2) to plain RTN."""
        w = jnp.asarray(rng.normal(size=(64, 48)), jnp.float32)
        qmax = jnp.float32(255.0)
        s1, z = quant.rtn_range(w, qmax)
        zeros = _lrq_inputs(np.random.default_rng(0), 64, 48, 4, 8, scale=0.0)
        out = lrq_fakequant_kernel(w, s1, z, zeros[3], zeros[4],
                                   zeros[5], zeros[6], qmax)
        rtn = quant.fakequant_weight(w, s1, z, jnp.zeros_like(w), qmax)
        assert_allclose(np.asarray(out), np.asarray(rtn), atol=1e-6)

    def test_grads_match_ste_oracle(self, rng):
        args = _lrq_inputs(rng, 64, 96, 8, 8)
        w, s1, z, l2, u2, r2, c2, qmax = args

        def loss_k(p):
            return (lrq_fakequant(w, p[0], z, p[1], p[2], p[3], p[4], qmax) ** 2).sum()

        def loss_r(p):
            return (ref.lrq_fakequant_ref(w, p[0], z, p[1], p[2], p[3], p[4], qmax) ** 2).sum()

        gk = jax.grad(loss_k)((s1, l2, u2, r2, c2))
        gr = jax.grad(loss_r)((s1, l2, u2, r2, c2))
        for a, b in zip(gk, gr):
            assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-4)

    def test_quantized_values_on_grid(self, rng):
        """Every Ŵ entry must equal s1[c] * k for integer k in [-z, qmax-z]."""
        args = _lrq_inputs(rng, 32, 40, 4, 4)
        w, s1, z, *_ , qmax = args
        out = np.asarray(lrq_fakequant_kernel(*args))
        codes = out / np.asarray(s1)[:, None]
        assert_allclose(codes, np.round(codes), atol=1e-4)
        assert codes.max() <= float(qmax) + 1e-4
        assert (codes + np.asarray(z)[:, None]).min() >= -1e-4


class TestPerTokenQuant:
    def test_matches_ref(self, rng):
        x = jnp.asarray(rng.normal(size=(4, 16, 96)), jnp.float32)
        qmax = jnp.float32(255.0)
        assert_allclose(np.asarray(per_token_quant_kernel(x, qmax)),
                        np.asarray(ref.per_token_quant_ref(x, qmax)),
                        atol=1e-6)

    @settings(max_examples=25, deadline=None)
    @given(
        t=st.sampled_from([1, 3, 8, 64, 256]),
        d=st.sampled_from([4, 32, 128, 352]),
        bits=st.sampled_from([4, 8]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hypothesis(self, t, d, bits, seed):
        rng = np.random.default_rng(seed)
        x = jnp.asarray(rng.normal(size=(t, d)) * 3.0, jnp.float32)
        qmax = jnp.float32(2.0 ** bits - 1.0)
        assert_allclose(np.asarray(per_token_quant_kernel(x, qmax)),
                        np.asarray(ref.per_token_quant_ref(x, qmax)),
                        atol=1e-5)

    def test_error_bound(self, rng):
        """|x - q(x)| <= scale/2 per token (asymmetric grid covers range)."""
        x = jnp.asarray(rng.normal(size=(32, 64)), jnp.float32)
        qmax = jnp.float32(255.0)
        out = np.asarray(per_token_quant_kernel(x, qmax))
        xn = np.asarray(x)
        span = (np.maximum(xn.max(1), 0) - np.minimum(xn.min(1), 0))
        bound = span / 255.0 / 2.0 + 1e-6
        assert (np.abs(out - xn).max(axis=1) <= bound).all()

    def test_grad_is_ste(self, rng):
        x = jnp.asarray(rng.normal(size=(8, 32)), jnp.float32)
        qmax = jnp.float32(255.0)
        gk = jax.grad(lambda x_: (per_token_quant(x_, qmax) ** 2).sum())(x)
        gr = jax.grad(lambda x_: (ref.per_token_quant_ref(x_, qmax) ** 2).sum())(x)
        assert_allclose(np.asarray(gk), np.asarray(gr), rtol=1e-4, atol=1e-4)


class TestQuantMatmul:
    def test_matches_ref(self, rng):
        x = jnp.asarray(rng.normal(size=(24, 64)), jnp.float32)
        w = jnp.asarray(rng.normal(size=(96, 64)), jnp.float32)
        qmax = jnp.float32(15.0)
        s1, z = quant.rtn_range(w, qmax)
        wq = quant.quantize_weight_int(w, s1, z, jnp.zeros_like(w), qmax)
        assert_allclose(np.asarray(quant_matmul(x, wq, s1, z)),
                        np.asarray(ref.quant_matmul_ref(x, wq, s1, z)),
                        rtol=1e-4, atol=1e-4)

    @settings(max_examples=20, deadline=None)
    @given(
        t=st.sampled_from([1, 7, 64]),
        k=st.sampled_from([16, 128]),
        n=st.sampled_from([8, 96, 352]),
        bits=st.sampled_from([3, 4, 8]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hypothesis(self, t, k, n, bits, seed):
        rng = np.random.default_rng(seed)
        x = jnp.asarray(rng.normal(size=(t, k)), jnp.float32)
        w = jnp.asarray(rng.normal(size=(n, k)), jnp.float32)
        qmax = jnp.float32(2.0 ** bits - 1.0)
        s1, z = quant.rtn_range(w, qmax)
        wq = quant.quantize_weight_int(w, s1, z, jnp.zeros_like(w), qmax)
        assert_allclose(np.asarray(quant_matmul(x, wq, s1, z)),
                        np.asarray(ref.quant_matmul_ref(x, wq, s1, z)),
                        rtol=1e-3, atol=1e-3)

    def test_dequant_equals_fp_matmul_of_dequant_weights(self, rng):
        x = jnp.asarray(rng.normal(size=(8, 32)), jnp.float32)
        w = jnp.asarray(rng.normal(size=(16, 32)), jnp.float32)
        qmax = jnp.float32(255.0)
        s1, z = quant.rtn_range(w, qmax)
        wq = quant.quantize_weight_int(w, s1, z, jnp.zeros_like(w), qmax)
        wd = (wq - z[:, None]) * s1[:, None]
        assert_allclose(np.asarray(quant_matmul(x, wq, s1, z)),
                        np.asarray(x @ wd.T), rtol=1e-4, atol=1e-4)
