"""L2 model-level tests: shapes, quant-flag dispatch, loss behaviour."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from compile import model as M
from compile import quant
from compile.configs import CONFIGS, block_weight_shapes, ACT_POINTS
from compile.train import param_spec, params_from_flat, make_train_step

CFG = CONFIGS["tiny"]


def make_block_weights(rng, cfg, scale=0.05):
    ws = tuple(jnp.asarray(rng.normal(size=sh) * scale, jnp.float32)
               for _, sh in block_weight_shapes(cfg))
    norms = (jnp.ones((cfg.d,), jnp.float32), jnp.ones((cfg.d,), jnp.float32))
    return ws, norms


def make_params(rng, cfg, scale=0.05):
    flat = []
    for _, sh in param_spec(cfg):
        if len(sh) == 1:
            flat.append(jnp.ones(sh, jnp.float32))
        else:
            flat.append(jnp.asarray(rng.normal(size=sh) * scale, jnp.float32))
    return params_from_flat(cfg, flat)


def fp_actq():
    static = {p: (jnp.float32(1.0), jnp.float32(0.0)) for p in ACT_POINTS}
    flags = (jnp.float32(0.0), jnp.float32(0.0), jnp.float32(0.0))
    return M.ActQuant(static, flags, jnp.float32(255.0), jnp.float32(255.0))


class TestBlock:
    def test_shapes(self, rng):
        ws, norms = make_block_weights(rng, CFG)
        x = jnp.asarray(rng.normal(size=(2, CFG.seq, CFG.d)), jnp.float32)
        y = M.block_fwd(CFG, ws, norms, x, M.NoQuant())
        assert y.shape == x.shape

    def test_flags_off_equals_fp(self, rng):
        """ActQuant with all flags 0 must equal the NoQuant path exactly."""
        ws, norms = make_block_weights(rng, CFG)
        x = jnp.asarray(rng.normal(size=(2, CFG.seq, CFG.d)), jnp.float32)
        y_fp = M.block_fwd(CFG, ws, norms, x, M.NoQuant())
        y_q = M.block_fwd(CFG, ws, norms, x, fp_actq())
        assert_allclose(np.asarray(y_q), np.asarray(y_fp), atol=1e-6)

    def test_act_quant_8bit_is_close(self, rng):
        ws, norms = make_block_weights(rng, CFG)
        x = jnp.asarray(rng.normal(size=(2, CFG.seq, CFG.d)), jnp.float32)
        y_fp = M.block_fwd(CFG, ws, norms, x, M.NoQuant())
        static = {p: (jnp.float32(1.0), jnp.float32(0.0)) for p in ACT_POINTS}
        aq = M.ActQuant(static, (jnp.float32(1.0), jnp.float32(1.0),
                                 jnp.float32(1.0)),
                        jnp.float32(255.0), jnp.float32(255.0))
        y_q = M.block_fwd(CFG, ws, norms, x, aq)
        rel = float(jnp.linalg.norm(y_q - y_fp) / jnp.linalg.norm(y_fp))
        assert 0.0 < rel < 0.05

    def test_per_token_worse_when_4bit(self, rng):
        """Lower activation bits must increase output error (monotone sanity)."""
        ws, norms = make_block_weights(rng, CFG)
        x = jnp.asarray(rng.normal(size=(2, CFG.seq, CFG.d)), jnp.float32)
        y_fp = M.block_fwd(CFG, ws, norms, x, M.NoQuant())
        errs = []
        for bits in (8.0, 4.0):
            static = {p: (jnp.float32(1.0), jnp.float32(0.0)) for p in ACT_POINTS}
            aq = M.ActQuant(static, (jnp.float32(1.0), jnp.float32(1.0),
                                     jnp.float32(0.0)),
                            jnp.float32(2.0 ** bits - 1.0), jnp.float32(255.0))
            y_q = M.block_fwd(CFG, ws, norms, x, aq)
            errs.append(float(jnp.linalg.norm(y_q - y_fp)))
        assert errs[1] > errs[0]

    def test_stats_recorded(self, rng):
        ws, norms = make_block_weights(rng, CFG)
        x = jnp.asarray(rng.normal(size=(2, CFG.seq, CFG.d)), jnp.float32)
        nq = M.NoQuant()
        M.block_fwd(CFG, ws, norms, x, nq)
        assert set(nq.stats) == set(ACT_POINTS)
        for p in ACT_POINTS:
            mn, mx, amax = nq.stats[p]
            assert float(mn) <= 0.0 <= float(mx)
            assert amax.ndim == 1

    def test_causality(self, rng):
        """Changing a future token must not affect past outputs."""
        ws, norms = make_block_weights(rng, CFG)
        x = jnp.asarray(rng.normal(size=(1, CFG.seq, CFG.d)), jnp.float32)
        y1 = M.block_fwd(CFG, ws, norms, x, M.NoQuant())
        x2 = x.at[0, -1].set(x[0, -1] + 10.0)
        y2 = M.block_fwd(CFG, ws, norms, x2, M.NoQuant())
        assert_allclose(np.asarray(y1[0, :-1]), np.asarray(y2[0, :-1]),
                        atol=1e-5)


class TestRope:
    def test_norm_preserved(self, rng):
        x = jnp.asarray(rng.normal(size=(2, 8, 4, 32)), jnp.float32)
        y = M.rope(x)
        assert_allclose(np.asarray(jnp.linalg.norm(y, axis=-1)),
                        np.asarray(jnp.linalg.norm(x, axis=-1)), rtol=1e-5)

    def test_position_zero_identity(self, rng):
        x = jnp.asarray(rng.normal(size=(1, 4, 2, 16)), jnp.float32)
        y = M.rope(x)
        assert_allclose(np.asarray(y[:, 0]), np.asarray(x[:, 0]), atol=1e-6)


class TestHead:
    def test_logprobs_are_logprobs(self, rng):
        b, s = 2, 8
        x = jnp.asarray(rng.normal(size=(b, s, CFG.d)), jnp.float32)
        head = jnp.asarray(rng.normal(size=(CFG.vocab, CFG.d)) * 0.1,
                           jnp.float32)
        tgt = jnp.asarray(rng.integers(0, CFG.vocab, size=(b, s)), jnp.int32)
        loss, logp = M.head_logprobs(x, jnp.ones((CFG.d,), jnp.float32),
                                     head, tgt)
        assert logp.shape == (b, s)
        assert (np.asarray(logp) <= 1e-5).all()
        assert_allclose(float(loss), -float(logp.mean()), rtol=1e-5)


class TestTrainStep:
    def test_loss_decreases(self, rng):
        cfg = CFG
        step = make_train_step(cfg)
        params = make_params(rng, cfg)
        zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
        m, v = zeros, zeros
        ids = jnp.asarray(rng.integers(0, cfg.vocab,
                                       size=(cfg.train_batch, cfg.seq)),
                          jnp.int32)
        # learnable: repeat same batch; loss must drop
        tgt = jnp.roll(ids, -1, axis=1)
        losses = []
        t = jnp.float32(0.0)
        lr = jnp.float32(1e-3)
        for i in range(5):
            loss, params, m, v = step(params, m, v, ids, tgt, t + i, lr)
            losses.append(float(loss))
        assert losses[-1] < losses[0]
